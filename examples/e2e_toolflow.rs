//! End-to-end toolflow driver (DESIGN.md §6): exercises every layer of the
//! system on a real small workload and proves they compose.
//!
//!   1. profile two networks on the simulated TX2 (L3 substrate),
//!   2. fit Γ/Φ random forests (L3),
//!   3. evaluate on held-out pruned topologies (paper-shape errors),
//!   4. export the Γ forest as tensors, load `forest_b*.hlo.txt` through
//!      PJRT and cross-check XLA (L1 Pallas kernel) vs native numerics,
//!   5. run a constrained OFA evolutionary search with model-predicted
//!      attributes through the XLA path.
//!
//! Run after `make artifacts`: `cargo run --release --example e2e_toolflow`

use perf4sight::device::{Simulator, PROFILE_COST_S};
use perf4sight::experiments::ofa_models::forward_masked;
use perf4sight::features::network_features_from_plan;
use perf4sight::forest::Forest;
use perf4sight::ir::NetworkPlan;
use perf4sight::models;
use perf4sight::ofa::{
    evolutionary_search, Attributes, Constraints, EsConfig, PlanOracle, Subset,
};
use perf4sight::profiler::train_test_split;
use perf4sight::pruning::Strategy;
use perf4sight::runtime::{forest_exec::export_forest_config, ForestExecutor, Runtime};

fn main() -> anyhow::Result<()> {
    let sim = Simulator::tx2();
    println!("=== 1. network-wise profiling (simulated {}) ===", sim.spec.name);
    let r18 = models::resnet18(1000);
    let sq = models::squeezenet(1000);
    let (train_a, test_a) = train_test_split(&sim, "resnet18", &r18, Strategy::Random, 11);
    let (train_b, test_b) = train_test_split(&sim, "squeezenet", &sq, Strategy::L1Norm, 13);
    println!(
        "  {} + {} train points, {} + {} test points",
        train_a.len(),
        train_b.len(),
        test_a.len(),
        test_b.len()
    );

    println!("\n=== 2. fit Γ/Φ forests ===");
    let mut train = train_a;
    train.extend(train_b);
    let cfg = export_forest_config();
    let fg = Forest::fit(&train.x(), &train.y_gamma(), &cfg);
    let fp = Forest::fit(&train.x(), &train.y_phi(), &cfg);

    println!("\n=== 3. held-out evaluation ===");
    for (name, test) in [("resnet18/rand", &test_a), ("squeezenet/L1", &test_b)] {
        println!(
            "  {name}: Γ err {:.2}%  Φ err {:.2}%  (paper worst-case: 9.15% / 14.7%)",
            fg.mape(&test.x(), &test.y_gamma()),
            fp.mape(&test.x(), &test.y_phi())
        );
    }

    println!("\n=== 4. XLA runtime cross-check (L1 pallas forest kernel) ===");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        Runtime::artifacts_present(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::cpu(&dir)?;
    let exec = ForestExecutor::new(&rt, &fg)?;
    let rows: Vec<Vec<f64>> = test_a.x().into_iter().take(64).collect();
    let native: Vec<f64> = rows.iter().map(|r| fg.predict(r)).collect();
    let via_xla = exec.predict_batch(&rows)?;
    let max_rel = native
        .iter()
        .zip(&via_xla)
        .map(|(a, b)| ((a - b) / a).abs())
        .fold(0.0f64, f64::max);
    println!("  64 predictions: max |native - xla| / native = {max_rel:.2e}");
    anyhow::ensure!(max_rel < 1e-4, "XLA path diverged from native forest");

    println!("\n=== 5. constrained OFA search with model-predicted attributes ===");
    let predict = |_c: &perf4sight::ofa::SubnetConfig, plan: &NetworkPlan| {
        // Γ through the XLA artifact (the deployed path); γ/φ natively.
        // One compiled plan per candidate serves both feature rows.
        let ft = network_features_from_plan(plan, 32);
        let fi = forward_masked(&network_features_from_plan(plan, 1));
        Attributes {
            gamma_train_mb: exec.predict_one(&ft).unwrap(),
            gamma_infer_mb: fg.predict(&fi).max(1500.0), // coarse reuse for the demo
            phi_infer_ms: fp.predict(&fi).max(5.0) / 20.0,
        }
    };
    let cons = Constraints {
        gamma_train_mb: 5200.0,
        gamma_infer_mb: f64::INFINITY,
        phi_infer_ms: f64::INFINITY,
    };
    let es = EsConfig {
        population: 24,
        iterations: 8,
        ..Default::default()
    };
    // The XLA-backed closure plugs into the same oracle seam the batched
    // PredictionEngine implements.
    let result = evolutionary_search(&cons, &es, Subset::City, &mut PlanOracle::new(predict));
    let naive_h = result.samples as f64 * PROFILE_COST_S / 3600.0;
    println!(
        "  best {:?}\n  predicted acc {:.1}%  attrs {:?}",
        result.best, result.best_fitness, result.best_attrs
    );
    println!(
        "  {} candidates in {:.2?}; naive profiling would need {:.1} h ({:.0}x slower)",
        result.samples,
        result.elapsed,
        naive_h,
        naive_h * 3600.0 / result.elapsed.as_secs_f64().max(1e-9)
    );
    println!("\nall five stages composed — toolflow OK");
    Ok(())
}
