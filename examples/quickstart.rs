//! Quickstart: the 60-second tour of the public API.
//!
//! Profile a network on the simulated Jetson TX2, fit the paper's two
//! random-forest models, and predict the training memory footprint (Γ) and
//! mini-batch latency (Φ) of an unseen pruned topology.
//!
//! Run: `cargo run --release --example quickstart`

use perf4sight::device::Simulator;
use perf4sight::features::network_features_from_plan;
use perf4sight::forest::Forest;
use perf4sight::models;
use perf4sight::profiler::{profile, ProfileJob};
use perf4sight::pruning::{prune, Strategy};
use perf4sight::runtime::forest_exec::export_forest_config;
use perf4sight::util::rng::Pcg64;

fn main() {
    // 1. A target device (the paper's testbed) and a network from the zoo.
    let sim = Simulator::tx2();
    let resnet18 = models::resnet18(1000);

    // 2. Network-wise profiling: each datapoint is an entire training step
    //    of a pruned topology at some batch size (Sec. 5.1).
    println!("profiling resnet18 on {} …", sim.spec.name);
    let dataset = profile(&sim, &ProfileJob::new("resnet18", &resnet18));
    println!("  {} datapoints (5 pruning levels × 25 batch sizes)", dataset.len());

    // 3. Fit the Γ and Φ random forests on the analytical features. The
    //    presorted train matrix is built once and shared by both fits.
    let cfg = export_forest_config();
    let m = dataset.train_matrix().unwrap();
    let gamma_model = Forest::fit_matrix(&m, &dataset.y_gamma(), &cfg).unwrap();
    let phi_model = Forest::fit_matrix(&m, &dataset.y_phi(), &cfg).unwrap();

    // 4. Predict an *unseen* topology: 40% L1-norm pruning, batch size 48.
    //    One compiled NetworkPlan serves both the analytical features and
    //    the ground-truth simulation (prune ⇒ rebuild plan).
    let mut rng = Pcg64::new(7);
    let pruned = prune(&resnet18, Strategy::L1Norm, 0.40, &mut rng);
    let plan = pruned.plan().unwrap();
    let feats = network_features_from_plan(&plan, 48);
    let gamma_pred = gamma_model.predict(&feats);
    let phi_pred = phi_model.predict(&feats);

    // 5. Compare against the simulated ground truth.
    let truth = sim.train_step_plan(&plan, 48, None);
    println!("\nresnet18 @ 40% L1 pruning, bs=48:");
    println!(
        "  Γ predicted {gamma_pred:>8.1} MB   measured {:>8.1} MB   ({:+.2}% error)",
        truth.gamma_mb,
        100.0 * (gamma_pred - truth.gamma_mb) / truth.gamma_mb
    );
    println!(
        "  Φ predicted {phi_pred:>8.1} ms   measured {:>8.1} ms   ({:+.2}% error)",
        truth.phi_ms,
        100.0 * (phi_pred - truth.phi_ms) / truth.phi_ms
    );
    println!("\n(see examples/e2e_toolflow.rs for the full pipeline incl. the XLA runtime)");
}
