//! OFA case-study example (Sec. 6.4): fit the three attribute models,
//! search the elastic OFA-ResNet50 space under hard constraints for each
//! of the four autonomous-driving subsets, and report the selected
//! sub-networks with their retraining gains.
//!
//! Run: `cargo run --release --example ofa_search`

use perf4sight::device::{Simulator, PROFILE_COST_S};
use perf4sight::experiments::ofa_models::{self, forward_masked};
use perf4sight::features::network_features;
use perf4sight::ofa::{
    evolutionary_search, initial_accuracy, retrained_accuracy, Attributes, Constraints,
    EsConfig, SubnetConfig, ALL_SUBSETS,
};

fn main() {
    let sim = Simulator::tx2();
    println!("fitting OFA attribute models (40 sampled sub-networks)…");
    let models = ofa_models::run(&sim, 40, 0x0fa5);
    ofa_models::print(&models.report);

    let predict = |_c: &SubnetConfig, g: &perf4sight::ir::Graph| Attributes {
        gamma_train_mb: models.gamma_train.predict(&network_features(g, 32).unwrap()),
        gamma_infer_mb: models
            .gamma_infer
            .predict(&forward_masked(&network_features(g, 1).unwrap())),
        phi_infer_ms: models
            .phi_infer
            .predict(&forward_masked(&network_features(g, 1).unwrap())),
    };

    // Budgets between the predicted MIN and MAX attribute extremes.
    let p_max = predict(&SubnetConfig::max(), &SubnetConfig::max().build());
    let p_min = predict(&SubnetConfig::min(), &SubnetConfig::min().build());
    let mid = |lo: f64, hi: f64| lo + 0.4 * (hi - lo);
    let cons = Constraints {
        gamma_train_mb: mid(p_min.gamma_train_mb, p_max.gamma_train_mb),
        gamma_infer_mb: mid(p_min.gamma_infer_mb, p_max.gamma_infer_mb),
        phi_infer_ms: mid(p_min.phi_infer_ms, p_max.phi_infer_ms),
    };
    println!(
        "\nconstraints: Γ ≤ {:.0} MB, γ ≤ {:.0} MB, φ ≤ {:.1} ms",
        cons.gamma_train_mb, cons.gamma_infer_mb, cons.phi_infer_ms
    );

    let es = EsConfig {
        population: 50,
        iterations: 60,
        ..Default::default()
    };
    for subset in ALL_SUBSETS {
        let result = evolutionary_search(&cons, &es, subset, predict);
        let g = result.best.build();
        let init = initial_accuracy(&result.best, &g, subset);
        let ret = retrained_accuracy(&result.best, &g, subset);
        let naive_h = result.samples as f64 * PROFILE_COST_S / 3600.0;
        println!(
            "\n{:<13} best {:?}\n              size {:.0} MB | top-1 {:.1}% → {:.1}% after retraining \
             | {} samples in {:.2?} (naive: {:.1} h)",
            subset.name(),
            result.best,
            g.model_size_mb().unwrap(),
            init,
            ret,
            result.samples,
            result.elapsed,
            naive_h
        );
    }
}
