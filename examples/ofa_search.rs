//! OFA case-study example (Sec. 6.4): fit the three attribute models,
//! search the elastic OFA-ResNet50 space under hard constraints for each
//! of the four autonomous-driving subsets, and report the selected
//! sub-networks with their retraining gains.
//!
//! Run: `cargo run --release --example ofa_search`

use perf4sight::device::{Simulator, PROFILE_COST_S};
use perf4sight::experiments::ofa_models::{self, forward_masked};
use perf4sight::features::network_features_from_plan;
use perf4sight::ir::NetworkPlan;
use perf4sight::ofa::{
    evolutionary_search, initial_accuracy, retrained_accuracy, Attributes, Constraints,
    EsConfig, SubnetConfig, ALL_SUBSETS,
};

fn main() {
    let sim = Simulator::tx2();
    println!("fitting OFA attribute models (40 sampled sub-networks)…");
    let models = ofa_models::run(&sim, 40, 0x0fa5);
    ofa_models::print(&models.report);

    // The search hands each candidate's compiled NetworkPlan to the
    // predictor: one analysis pass serves the bs=32 training features and
    // the shared bs=1 inference features.
    let predict = |_c: &SubnetConfig, plan: &NetworkPlan| {
        let f_train = network_features_from_plan(plan, 32);
        let f_infer = forward_masked(&network_features_from_plan(plan, 1));
        Attributes {
            gamma_train_mb: models.gamma_train.predict(&f_train),
            gamma_infer_mb: models.gamma_infer.predict(&f_infer),
            phi_infer_ms: models.phi_infer.predict(&f_infer),
        }
    };

    // Budgets between the predicted MIN and MAX attribute extremes.
    let g_max = SubnetConfig::max().build();
    let g_min = SubnetConfig::min().build();
    let p_max = predict(&SubnetConfig::max(), &NetworkPlan::build(&g_max).unwrap());
    let p_min = predict(&SubnetConfig::min(), &NetworkPlan::build(&g_min).unwrap());
    let mid = |lo: f64, hi: f64| lo + 0.4 * (hi - lo);
    let cons = Constraints {
        gamma_train_mb: mid(p_min.gamma_train_mb, p_max.gamma_train_mb),
        gamma_infer_mb: mid(p_min.gamma_infer_mb, p_max.gamma_infer_mb),
        phi_infer_ms: mid(p_min.phi_infer_ms, p_max.phi_infer_ms),
    };
    println!(
        "\nconstraints: Γ ≤ {:.0} MB, γ ≤ {:.0} MB, φ ≤ {:.1} ms",
        cons.gamma_train_mb, cons.gamma_infer_mb, cons.phi_infer_ms
    );

    let es = EsConfig {
        population: 50,
        iterations: 60,
        ..Default::default()
    };
    for subset in ALL_SUBSETS {
        let result = evolutionary_search(&cons, &es, subset, predict);
        let g = result.best.build();
        let init = initial_accuracy(&result.best, &g, subset);
        let ret = retrained_accuracy(&result.best, &g, subset);
        let naive_h = result.samples as f64 * PROFILE_COST_S / 3600.0;
        println!(
            "\n{:<13} best {:?}\n              size {:.0} MB | top-1 {:.1}% → {:.1}% after retraining \
             | {} samples in {:.2?} (naive: {:.1} h)",
            subset.name(),
            result.best,
            g.model_size_mb().unwrap(),
            init,
            ret,
            result.samples,
            result.elapsed,
            naive_h
        );
    }
}
