//! OFA case-study example (Sec. 6.4): fit the three attribute models,
//! compile them into the batched `PredictionEngine`, and search the
//! elastic OFA-ResNet50 space under hard constraints for each of the four
//! autonomous-driving subsets.
//!
//! One engine serves all four searches: every generation's (Γ, γ, φ)
//! estimates are answered in three batched `predict_rows` calls, and
//! candidates revisited within or across searches hit the fingerprint
//! memo cache instead of being re-evaluated.
//!
//! Run: `cargo run --release --example ofa_search`

use perf4sight::device::{Simulator, PROFILE_COST_S};
use perf4sight::experiments::ofa_models;
use perf4sight::ofa::{
    evolutionary_search, initial_accuracy, retrained_accuracy, Constraints, EsConfig,
    GenerationOracle, SubnetConfig, ALL_SUBSETS,
};

fn main() {
    let sim = Simulator::tx2();
    println!("fitting OFA attribute models (40 sampled sub-networks)…");
    let models = ofa_models::run(&sim, 40, 0x0fa5);
    ofa_models::print(&models.report);

    let mut engine = models.engine();

    // Budgets between the predicted MIN and MAX attribute extremes.
    let anchors = engine.evaluate_generation(&[SubnetConfig::max(), SubnetConfig::min()]);
    let (p_max, p_min) = (anchors[0].attrs, anchors[1].attrs);
    let mid = |lo: f64, hi: f64| lo + 0.4 * (hi - lo);
    let cons = Constraints {
        gamma_train_mb: mid(p_min.gamma_train_mb, p_max.gamma_train_mb),
        gamma_infer_mb: mid(p_min.gamma_infer_mb, p_max.gamma_infer_mb),
        phi_infer_ms: mid(p_min.phi_infer_ms, p_max.phi_infer_ms),
    };
    println!(
        "\nconstraints: Γ ≤ {:.0} MB, γ ≤ {:.0} MB, φ ≤ {:.1} ms",
        cons.gamma_train_mb, cons.gamma_infer_mb, cons.phi_infer_ms
    );

    let es = EsConfig {
        population: 50,
        iterations: 60,
        ..Default::default()
    };
    for subset in ALL_SUBSETS {
        let result = evolutionary_search(&cons, &es, subset, &mut engine);
        let g = result.best.build();
        let init = initial_accuracy(&result.best, &g, subset);
        let ret = retrained_accuracy(&result.best, &g, subset);
        let naive_h = result.samples as f64 * PROFILE_COST_S / 3600.0;
        let hit_rate = result.cache.map(|c| 100.0 * c.hit_rate()).unwrap_or(0.0);
        println!(
            "\n{:<13} best {:?}\n              size {:.0} MB | top-1 {:.1}% → {:.1}% after retraining \
             | {} samples ({} unique evaluations, {:.0}% cache hits) in {:.2?} (naive: {:.1} h)",
            subset.name(),
            result.best,
            g.model_size_mb().unwrap(),
            init,
            ret,
            result.samples,
            result.unique_evaluations,
            hit_rate,
            result.elapsed,
            naive_h
        );
    }
    let total = engine.stats();
    println!(
        "\nengine totals across all searches: {} requests, {:.1}% served from cache, {} live entries",
        total.requests(),
        100.0 * total.hit_rate(),
        total.entries
    );
}
