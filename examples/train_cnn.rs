//! End-to-end training demo: trains the L2 CNN — whose convolutions are
//! the L1 Pallas kernels (Eqs. 1-3) — for several hundred SGD steps on a
//! synthetic 10-class vision task, entirely through the AOT-compiled
//! `trainstep.hlo.txt` artifact executed from Rust via PJRT. Python never
//! runs. Logs the loss curve and final train accuracy; recorded in
//! EXPERIMENTS.md.
//!
//! Run after `make artifacts`: `cargo run --release --example train_cnn`

use perf4sight::runtime::{trainstep_exec, Runtime, TrainState, TrainStepExecutor};
use perf4sight::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        Runtime::artifacts_present(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::cpu(&dir)?;
    let exec = TrainStepExecutor::new(&rt)?;
    let mut state = TrainState::init(42);
    let mut rng = Pcg64::new(0x7ea1);

    let steps = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300usize);
    let lr = 0.08f32;

    println!("training 3-conv CNN (pallas kernels) for {steps} steps, bs=64, lr={lr}");
    let started = std::time::Instant::now();
    let mut curve: Vec<(usize, f64)> = Vec::new();
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for step in 0..steps {
        let (x, y) = trainstep_exec::synthetic_batch(&mut rng);
        let loss = exec.step(&mut state, &x, &y, lr)?;
        if step == 0 {
            first = loss;
        }
        last = loss;
        if step % 20 == 0 || step == steps - 1 {
            println!("  step {step:>4}   loss {loss:.4}");
            curve.push((step, loss));
        }
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
    }
    let elapsed = started.elapsed();
    println!(
        "\nloss {first:.4} → {last:.4} over {steps} steps in {elapsed:.2?} \
         ({:.1} steps/s; {} images/s)",
        steps as f64 / elapsed.as_secs_f64(),
        (steps * trainstep_exec::TRAIN_BATCH) as f64 / elapsed.as_secs_f64()
    );
    anyhow::ensure!(
        last < first * 0.5,
        "training did not converge: {first:.4} → {last:.4}"
    );
    println!("loss curve (step, loss): {curve:?}");
    println!("end-to-end training through L1 pallas → L2 jax → AOT HLO → L3 rust: OK");
    Ok(())
}
